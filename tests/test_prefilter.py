"""Embedding-prefiltered join pipeline (DESIGN.md §14): candidate
generation semantics, recall-vs-k monotonicity, parity with the block
join at degenerate k, ledger accounting, the scored/decode/cascade
verification paths, the scaled marketplace scenario's planted truth,
and the EngineEmbedder serving path."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.core import (
    HashEmbedder,
    OracleLLM,
    block_join,
    embedding_join,
    prefilter_join,
    topk_candidates,
)
from repro.data.scenarios import (
    _market_match,
    _truth_set,
    all_scenarios,
    marketplace_scenario,
)
from repro.data.tokenizer import ByteTokenizer
from repro.models import init_params, model_specs
from repro.serve import Engine, EngineClient, EngineEmbedder

KEY = jax.random.PRNGKey(9)


@pytest.fixture(scope="module")
def market():
    return marketplace_scenario(n1=120, n2=60, n_products=5, n_cities=4,
                                seed=3)


@pytest.fixture(scope="module")
def engine_setup():
    cfg = get_smoke_config("mamba2-130m")
    params = init_params(model_specs(cfg), KEY, jnp.float32)
    tok = ByteTokenizer(cfg.vocab_size)
    return cfg, params, tok


# ---------------------------------------------------------------------------
# scaled marketplace scenario
# ---------------------------------------------------------------------------


def test_marketplace_planted_truth_matches_predicate():
    sc = marketplace_scenario(n1=80, n2=40, n_products=4, n_cities=3, seed=1)
    assert sc.truth == _truth_set(_market_match, sc.r1, sc.r2)
    assert 0.0 < sc.selectivity < 1.0


def test_marketplace_validates_sizes():
    with pytest.raises(ValueError):
        marketplace_scenario(n1=10, n2=10, n_products=999)
    with pytest.raises(ValueError):
        marketplace_scenario(n1=10, n2=10, n_cities=0)


# ---------------------------------------------------------------------------
# candidate generation
# ---------------------------------------------------------------------------


def test_topk_candidates_modes_and_validation():
    emb = HashEmbedder()
    e1 = np.asarray(emb.embed(["red apple", "green pear", "blue sky"]))
    e2 = np.asarray(emb.embed(["red apple pie", "clear blue sky"]))
    both = topk_candidates(e1, e2, 1)
    only1 = topk_candidates(e1, e2, 1, mode="r1")
    only2 = topk_candidates(e1, e2, 1, mode="r2")
    assert only1 | only2 == both
    assert len(only1) == 3 and len(only2) == 2  # one partner per valid row
    with pytest.raises(ValueError):
        topk_candidates(e1, e2, 0)
    with pytest.raises(ValueError):
        topk_candidates(e1, e2, 1, mode="r3")


def test_topk_candidates_excludes_zero_norm_rows():
    emb = HashEmbedder()
    e1 = np.asarray(emb.embed(["red", "", "blue"]))
    e2 = np.asarray(emb.embed(["", "red", "blue"]))
    cands = topk_candidates(e1, e2, 5)
    assert cands and all(i != 1 and k != 0 for i, k in cands)
    assert topk_candidates(np.zeros((3, 4)), e2, 2) == set()


def test_topk_candidates_kernel_path_agrees(market):
    emb = HashEmbedder()
    e1 = np.asarray(emb.embed(market.r1))
    e2 = np.asarray(emb.embed(market.r2))
    assert (topk_candidates(e1, e2, 4, use_kernel=True)
            == topk_candidates(e1, e2, 4))


# ---------------------------------------------------------------------------
# prefilter join: recall/quality/accounting
# ---------------------------------------------------------------------------


def test_prefilter_recall_monotone_in_k(market):
    oracle = OracleLLM(market.predicate, context_limit=100_000)
    prev = -1.0
    for k in (1, 2, 4, 8, 16, 60):
        res = prefilter_join(market.r1, market.r2, market.condition,
                             oracle, k=k)
        cand = set(res.meta["candidate_pairs"])
        recall = len(cand & market.truth) / len(market.truth)
        assert recall >= prev - 1e-12
        prev = recall
        # exact-oracle verification admits no false positives
        assert res.pairs <= market.truth
        assert res.pairs == cand & market.truth
    # k >= |r2| degenerates to the full cross product: perfect recall
    assert prev == 1.0


def test_prefilter_matches_block_join_on_seed_scenarios():
    """At degenerate k the prefilter must reproduce the block join
    exactly on the paper's three scenarios."""
    for sc in all_scenarios():
        oracle = OracleLLM(sc.predicate, context_limit=100_000)
        res = prefilter_join(sc.r1, sc.r2, sc.condition, oracle,
                             k=max(len(sc.r1), len(sc.r2)))
        blk = block_join(sc.r1, sc.r2, sc.condition,
                         OracleLLM(sc.predicate, context_limit=100_000),
                         8, 8)
        assert res.pairs == blk.pairs == sc.truth
        assert res.meta["candidate_fraction"] == 1.0


def test_prefilter_beats_argmax_embedding_join(market):
    """The embedding baseline *decides* with argmax; the prefilter only
    *generates* with top-k and lets the LLM decide."""
    oracle = OracleLLM(market.predicate, context_limit=100_000)
    res = prefilter_join(market.r1, market.r2, market.condition, oracle, k=8)
    base = embedding_join(market.r1, market.r2, market.condition)
    assert res.f1(market.truth) > base.f1(market.truth)


def test_prefilter_ledger_accounting(market):
    oracle = OracleLLM(market.predicate, context_limit=100_000)
    res = prefilter_join(market.r1, market.r2, market.condition, oracle, k=4)
    # two embedding calls + one scoring call per candidate, zero decode
    assert res.ledger.calls == 2 + res.meta["candidates"]
    assert res.ledger.completion_tokens == 0
    assert res.ledger.scored_tokens > 0
    assert res.meta["scoring"] is True
    emb = HashEmbedder()
    emb.embed(market.r1)
    emb.embed(market.r2)
    embed_tokens = emb.tokens_read
    assert res.ledger.prompt_tokens > embed_tokens > 0


def test_prefilter_decode_fallback_matches_scoring(market):
    mk = lambda: OracleLLM(market.predicate, context_limit=100_000)
    scored = prefilter_join(market.r1, market.r2, market.condition, mk(),
                            k=4)
    decoded = prefilter_join(market.r1, market.r2, market.condition, mk(),
                             k=4, scoring=False, max_answer_tokens=4)
    assert decoded.pairs == scored.pairs
    assert decoded.ledger.completion_tokens > 0
    assert decoded.ledger.scored_tokens == 0


def test_prefilter_cascade_over_candidates(market):
    noisy = OracleLLM(market.predicate, context_limit=100_000,
                      fn_rate=0.3, fp_rate=0.3, noise_seed=11)
    exact = OracleLLM(market.predicate, context_limit=100_000)
    res = prefilter_join(market.r1, market.r2, market.condition, noisy,
                         k=60, large=exact, threshold=0.5)
    # wrong noisy decisions sit below threshold: escalation corrects them
    assert res.pairs == market.truth
    assert res.meta["escalated"] > 0
    assert res.meta["tiers"]["large"]["calls"] == res.meta["escalated"]


def test_prefilter_validation(market):
    oracle = OracleLLM(market.predicate)
    with pytest.raises(ValueError):
        prefilter_join(market.r1, market.r2, "", oracle, mode="r3")
    with pytest.raises(ValueError):
        prefilter_join(market.r1, market.r2, "", oracle, k=0)
    with pytest.raises(ValueError):
        prefilter_join(market.r1, market.r2, "", oracle,
                       large=oracle, threshold=1.5)


# ---------------------------------------------------------------------------
# EngineEmbedder serving path
# ---------------------------------------------------------------------------


def test_engine_embedder_determinism_and_accounting(engine_setup):
    cfg, params, tok = engine_setup
    eng = Engine(cfg, params, tok, max_seq=128, slots=4)
    texts = ["hello world", "a longer text to embed right here",
             "x", "hello world"] * 2
    emb = EngineEmbedder(eng)
    vecs = np.asarray(emb.embed(texts))
    assert vecs.shape == (len(texts), cfg.d_model)
    assert emb.batches == 2  # 8 texts through 4 slots
    assert emb.tokens_read == sum(len(tok.encode(t)) for t in texts)
    np.testing.assert_allclose(np.linalg.norm(vecs, axis=1), 1.0, atol=1e-9)
    # identical texts embed identically, across different batches
    np.testing.assert_array_equal(vecs[0], vecs[3])
    np.testing.assert_array_equal(vecs[:4], vecs[4:])
    # a second pass reproduces the vectors exactly
    np.testing.assert_array_equal(np.asarray(emb.embed(texts)), vecs)


def test_engine_embedder_bucket_independence(engine_setup):
    """The same text embeds identically alone (small bucket) and next to
    a long neighbour (large bucket): right-padding never leaks in."""
    cfg, params, tok = engine_setup
    eng = Engine(cfg, params, tok, max_seq=128, slots=4)
    short = "tiny"
    alone, _ = eng.embed_rows([short])
    padded, _ = eng.embed_rows([short, "a much longer companion text " * 3])
    np.testing.assert_allclose(alone[0], padded[0], atol=1e-5)


def test_engine_embedder_backend_validation(engine_setup):
    cfg, params, tok = engine_setup
    eng = Engine(cfg, params, tok, max_seq=128, slots=2)
    assert EngineEmbedder(EngineClient(eng)).dim == cfg.d_model
    with pytest.raises(TypeError):
        EngineEmbedder(object())
    with pytest.raises(ValueError):
        eng.embed_rows([])
    with pytest.raises(ValueError):
        eng.embed_rows(["a"] * 3)  # > slots
    with pytest.raises(ValueError):
        eng.embed_rows(["x" * 500])  # > max_seq


def test_prefilter_engine_end_to_end(engine_setup):
    """Marketplace through the serving tier: engine embeddings for
    candidates, teacher-forced engine scoring for verification."""
    cfg, params, tok = engine_setup
    sc = marketplace_scenario(n1=24, n2=12, n_products=3, n_cities=2, seed=5)
    eng = Engine(cfg, params, tok, max_seq=512, slots=4)
    client = EngineClient(
        eng, oracle=OracleLLM(sc.predicate, context_limit=100_000))
    emb = EngineEmbedder(client)
    res = prefilter_join(sc.r1, sc.r2, sc.condition, client, emb, k=3)
    assert client.executor.stats.decode_steps == 0
    assert res.ledger.calls == 2 + res.meta["candidates"]
    assert res.ledger.scored_tokens > 0
    # oracle-forced verification: no false positives whatever the
    # random-weight embeddings propose
    assert res.pairs <= sc.truth
    assert res.precision(sc.truth) == 1.0 if res.pairs else True
