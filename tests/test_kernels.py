"""Per-kernel allclose sweeps vs the pure-jnp oracles (interpret mode)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

KEY = jax.random.PRNGKey(42)


def _tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 else dict(rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "B,S,H,KV,hd,chunk",
    [
        (1, 32, 2, 2, 16, 16),     # MHA
        (2, 64, 4, 2, 32, 16),     # GQA 2:1
        (1, 96, 8, 2, 16, 32),     # GQA 4:1, S not a power of two
        (2, 64, 6, 3, 8, 16),      # odd head count (starcoder-like)
        (1, 128, 4, 1, 64, 64),    # MQA, big head_dim
    ],
)
def test_flash_attention(B, S, H, KV, hd, chunk, dtype):
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, S, H, hd), dtype)
    k = jax.random.normal(ks[1], (B, S, KV, hd), dtype)
    v = jax.random.normal(ks[2], (B, S, KV, hd), dtype)
    out = ops.flash_attention(q, k, v, chunk=chunk)
    gold = ref.flash_attention_ref(q, k, v)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(gold, np.float32), **_tol(dtype))


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "B,S,P,H,KV,hd,chunk",
    [
        (2, 32, 64, 4, 2, 16, 16),    # GQA 2:1, prefix longer than suffix
        (1, 48, 32, 6, 3, 8, 16),     # odd head count
        (2, 1, 16, 4, 1, 32, 512),    # 1-token uncached suffix (full hit)
        (3, 16, 128, 4, 2, 16, 8),    # long ragged prefix
    ],
)
def test_chunked_prefill_attention(B, S, P, H, KV, hd, chunk, dtype):
    """Chunked-prefill kernel vs oracle on ragged cached-prefix lengths."""
    ks = jax.random.split(KEY, 5)
    q = jax.random.normal(ks[0], (B, S, H, hd), dtype)
    k = jax.random.normal(ks[1], (B, S, KV, hd), dtype)
    v = jax.random.normal(ks[2], (B, S, KV, hd), dtype)
    kp = jax.random.normal(ks[3], (B, P, KV, hd), dtype)
    vp = jax.random.normal(ks[4], (B, P, KV, hd), dtype)
    ragged = jax.random.randint(jax.random.PRNGKey(B * S), (B,), 0, P + 1)
    for plen in (
        jnp.zeros((B,), jnp.int32),            # no cache hit at all
        jnp.full((B,), P, jnp.int32),          # prefix buffer exactly full
        jnp.full((B,), min(chunk, P), jnp.int32),  # exactly on a block edge
        ragged.astype(jnp.int32),              # ragged, page-unaligned
    ):
        out = ops.chunked_prefill_attention(q, k, v, kp, vp, plen, chunk=chunk)
        gold = ref.chunked_prefill_attention_ref(q, k, v, kp, vp, plen)
        np.testing.assert_allclose(np.asarray(out, np.float32),
                                   np.asarray(gold, np.float32), **_tol(dtype))


def test_chunked_prefill_with_zero_prefix_equals_flash():
    """With prefix_len=0 everywhere the kernel must reduce to plain causal
    attention over the suffix (the cold-cache path)."""
    B, S, P, H, KV, hd = 2, 64, 32, 4, 2, 16
    ks = jax.random.split(KEY, 5)
    q = jax.random.normal(ks[0], (B, S, H, hd), jnp.float32)
    k = jax.random.normal(ks[1], (B, S, KV, hd), jnp.float32)
    v = jax.random.normal(ks[2], (B, S, KV, hd), jnp.float32)
    kp = jax.random.normal(ks[3], (B, P, KV, hd), jnp.float32)
    vp = jax.random.normal(ks[4], (B, P, KV, hd), jnp.float32)
    plen = jnp.zeros((B,), jnp.int32)
    out = ops.chunked_prefill_attention(q, k, v, kp, vp, plen, chunk=16)
    gold = ref.flash_attention_ref(q, k, v)
    np.testing.assert_allclose(np.asarray(out), np.asarray(gold),
                               rtol=2e-5, atol=2e-5)


def test_chunked_prefill_matches_xla_fallback():
    """The engine's CPU path (layers.chunked_prefill_attention) and the
    Pallas kernel must agree — the kernel parity contract of ops.py."""
    from repro.models import layers as L

    B, S, P, H, KV, hd = 2, 32, 48, 4, 2, 16
    ks = jax.random.split(KEY, 5)
    q = jax.random.normal(ks[0], (B, S, H, hd), jnp.float32)
    k = jax.random.normal(ks[1], (B, S, KV, hd), jnp.float32)
    v = jax.random.normal(ks[2], (B, S, KV, hd), jnp.float32)
    kp = jax.random.normal(ks[3], (B, P, KV, hd), jnp.float32)
    vp = jax.random.normal(ks[4], (B, P, KV, hd), jnp.float32)
    plen = jnp.asarray([16, 37], jnp.int32)
    G = H // KV
    rep = lambda a: jnp.repeat(a, G, axis=2)
    xla = L.chunked_prefill_attention(q, rep(k), rep(v), rep(kp), rep(vp), plen)
    pall = ops.chunked_prefill_attention(q, k, v, kp, vp, plen, chunk=16)
    np.testing.assert_allclose(np.asarray(xla), np.asarray(pall),
                               rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "B,Skv,H,KV,hd",
    [(1, 32, 2, 2, 16), (2, 64, 4, 2, 32), (3, 48, 8, 2, 16), (2, 128, 4, 1, 64)],
)
def test_decode_attention(B, Skv, H, KV, hd, dtype):
    ks = jax.random.split(KEY, 4)
    q = jax.random.normal(ks[0], (B, 1, H, hd), dtype)
    kc = jax.random.normal(ks[1], (B, Skv, KV, hd), dtype)
    vc = jax.random.normal(ks[2], (B, Skv, KV, hd), dtype)
    clen = jax.random.randint(ks[3], (B,), 1, Skv + 1)
    out = ops.decode_attention(q, kc, vc, clen)
    gold = ref.decode_attention_ref(q, kc, vc, clen)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(gold, np.float32), **_tol(dtype))


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "B,H,KV,hd,page,n_slots",
    [
        (1, 2, 2, 16, 4, 8),      # MHA, tiny pages
        (2, 4, 2, 32, 16, 4),     # GQA 2:1, engine-default page size
        (3, 8, 2, 16, 8, 6),      # GQA 4:1
        (2, 4, 1, 64, 16, 8),     # MQA, big head_dim
    ],
)
def test_paged_decode_attention(B, H, KV, hd, page, n_slots, dtype):
    """Paged decode kernel vs oracle: randomized (permuted) page tables
    and ragged lengths incl. exact page boundaries."""
    n_pages = B * n_slots + 3
    ks = jax.random.split(KEY, 4)
    q = jax.random.normal(ks[0], (B, 1, H, hd), dtype)
    kp = jax.random.normal(ks[1], (n_pages, page, KV, hd), dtype)
    vp = jax.random.normal(ks[2], (n_pages, page, KV, hd), dtype)
    rng = np.random.default_rng(B * page)
    table = jnp.asarray(
        rng.permutation(n_pages)[: B * n_slots].reshape(B, n_slots), jnp.int32)
    boundary = [1, page, page - 1 or 1, page + 1, n_slots * page][:B] or [1]
    for clen in (
        jnp.asarray((boundary * B)[:B], jnp.int32),        # page boundaries
        jax.random.randint(ks[3], (B,), 1, n_slots * page + 1),  # ragged
        jnp.full((B,), n_slots * page, jnp.int32),         # table fully valid
    ):
        out = ops.paged_decode_attention(q, kp, vp, table, clen)
        gold = ref.paged_decode_attention_ref(q, kp, vp, table, clen)
        np.testing.assert_allclose(np.asarray(out, np.float32),
                                   np.asarray(gold, np.float32), **_tol(dtype))


def test_paged_decode_matches_contiguous_decode():
    """A page table laid out contiguously must reproduce the dense decode
    kernel bit-for-bit on the valid prefix — the REPRO_PAGED_KV parity
    contract at the kernel level (also pins the XLA fallback)."""
    from repro.models import layers as L

    B, H, KV, hd, page, n_slots = 2, 4, 2, 16, 8, 4
    Skv = page * n_slots
    ks = jax.random.split(KEY, 4)
    q = jax.random.normal(ks[0], (B, 1, H, hd), jnp.float32)
    kc = jax.random.normal(ks[1], (B, Skv, KV, hd), jnp.float32)
    vc = jax.random.normal(ks[2], (B, Skv, KV, hd), jnp.float32)
    clen = jnp.asarray([page + 3, Skv], jnp.int32)
    # pool page (b * n_slots + s) holds row b's positions [s*page,(s+1)*page)
    kp = kc.reshape(B * n_slots, page, KV, hd)
    vp = vc.reshape(B * n_slots, page, KV, hd)
    table = jnp.arange(B * n_slots, dtype=jnp.int32).reshape(B, n_slots)
    dense = ops.decode_attention(q, kc, vc, clen)
    paged = ops.paged_decode_attention(q, kp, vp, table, clen)
    xla = L.paged_decode_attention(q, kp, vp, table, clen)
    np.testing.assert_allclose(np.asarray(paged), np.asarray(dense),
                               rtol=2e-5, atol=2e-5)
    np.testing.assert_array_equal(np.asarray(xla), np.asarray(
        L.decode_attention(q, kc, vc, clen)))  # fallback: bit-identical


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "B,K,H,KV,hd,page,n_slots",
    [
        (2, 4, 4, 2, 16, 8, 6),    # GQA 2:1
        (1, 6, 2, 2, 16, 4, 8),    # MHA, window longer than a page
        (3, 3, 8, 2, 16, 8, 6),    # GQA 4:1
        (2, 5, 4, 1, 64, 16, 4),   # MQA, big head_dim
    ],
)
def test_spec_verify_attention(B, K, H, KV, hd, page, n_slots, dtype):
    """Speculative-verification kernel vs oracle: permuted page tables,
    ragged lens including windows that straddle page boundaries, and the
    XLA fallback (which is a static loop of paged decode attention)."""
    from repro.models import layers as L

    n_pages = B * n_slots + 3
    ks = jax.random.split(KEY, 4)
    q = jax.random.normal(ks[0], (B, K, H, hd), dtype)
    kp = jax.random.normal(ks[1], (n_pages, page, KV, hd), dtype)
    vp = jax.random.normal(ks[2], (n_pages, page, KV, hd), dtype)
    rng = np.random.default_rng(B * page + K)
    table = jnp.asarray(
        rng.permutation(n_pages)[: B * n_slots].reshape(B, n_slots), jnp.int32)
    hi = n_slots * page - K
    straddle = [max(page - 1, 0), max(page - K // 2, 1), 2 * page - 1][:B]
    for clen in (
        jnp.asarray((straddle * B)[:B], jnp.int32),  # window crosses a page
        jax.random.randint(ks[3], (B,), 0, hi + 1),  # ragged, incl. len 0
        jnp.full((B,), hi, jnp.int32),               # table fully valid
    ):
        out = ops.spec_verify_attention(q, kp, vp, table, clen)
        gold = ref.spec_verify_attention_ref(q, kp, vp, table, clen)
        np.testing.assert_allclose(np.asarray(out, np.float32),
                                   np.asarray(gold, np.float32), **_tol(dtype))
        xla = L.spec_verify_attention_paged(q, kp, vp, table, clen)
        np.testing.assert_allclose(np.asarray(xla, np.float32),
                                   np.asarray(gold, np.float32), **_tol(dtype))


def test_spec_verify_k1_reduces_to_paged_decode():
    """K=1 must reproduce the single-token paged decode kernel (and the
    XLA fallbacks each other) bit-for-bit — the speculative window is a
    strict generalization, not a reimplementation."""
    from repro.models import layers as L

    B, H, KV, hd, page, n_slots = 2, 4, 2, 16, 8, 4
    n_pages = B * n_slots + 2
    ks = jax.random.split(KEY, 4)
    q = jax.random.normal(ks[0], (B, 1, H, hd), jnp.float32)
    kp = jax.random.normal(ks[1], (n_pages, page, KV, hd), jnp.float32)
    vp = jax.random.normal(ks[2], (n_pages, page, KV, hd), jnp.float32)
    table = jnp.arange(B * n_slots, dtype=jnp.int32).reshape(B, n_slots)
    clen = jnp.asarray([page - 1, 3 * page], jnp.int32)
    out = ops.spec_verify_attention(q, kp, vp, table, clen)
    dec = ops.paged_decode_attention(q, kp, vp, table, clen + 1)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(dec))
    xla = L.spec_verify_attention_paged(q, kp, vp, table, clen)
    xdec = L.paged_decode_attention(q, kp, vp, table, clen + 1)
    np.testing.assert_array_equal(np.asarray(xla), np.asarray(xdec))


def test_spec_verify_dense_fallback_matches_sequential_decode():
    """The dense verification fallback must equal K sequential decode
    -attention calls bit-for-bit (the REPRO_SPEC_DECODE greedy-parity
    contract), and the paged fallback must agree with it on a
    contiguously laid-out page table."""
    from repro.models import layers as L

    B, K, H, KV, hd, page, n_slots = 2, 4, 4, 2, 16, 8, 4
    Skv = page * n_slots
    ks = jax.random.split(KEY, 4)
    q = jax.random.normal(ks[0], (B, K, H, hd), jnp.float32)
    kc = jax.random.normal(ks[1], (B, Skv, KV, hd), jnp.float32)
    vc = jax.random.normal(ks[2], (B, Skv, KV, hd), jnp.float32)
    clen = jnp.asarray([page - 2, 2 * page], jnp.int32)
    out = L.spec_verify_attention(q, kc, vc, clen)
    seq = jnp.concatenate(
        [L.decode_attention(q[:, j:j + 1], kc, vc, clen + j + 1)
         for j in range(K)], axis=1)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(seq))
    kp = kc.reshape(B * n_slots, page, KV, hd)
    vp = vc.reshape(B * n_slots, page, KV, hd)
    table = jnp.arange(B * n_slots, dtype=jnp.int32).reshape(B, n_slots)
    paged = L.spec_verify_attention_paged(q, kp, vp, table, clen)
    np.testing.assert_array_equal(np.asarray(paged), np.asarray(out))


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "B,S,H,P,N,chunk",
    [(1, 32, 2, 8, 4, 8), (2, 64, 3, 16, 8, 16), (1, 48, 4, 8, 16, 12)],
)
def test_ssd_scan(B, S, H, P, N, chunk, dtype):
    ks = jax.random.split(KEY, 5)
    x = jax.random.normal(ks[0], (B, S, H, P), dtype)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, H), jnp.float32))
    A = -jnp.exp(jax.random.normal(ks[2], (H,), jnp.float32) * 0.5)
    b = jax.random.normal(ks[3], (B, S, N), dtype)
    c = jax.random.normal(ks[4], (B, S, N), dtype)
    out = ops.ssd_scan(x, dt, A, b, c, chunk=chunk)
    gold = ref.ssd_scan_ref(x, dt, A, b, c)
    tol = dict(rtol=5e-2, atol=5e-2) if dtype == jnp.bfloat16 else dict(rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(gold, np.float32), **tol)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("shape", [(8, 32), (4, 33, 64), (2, 5, 7, 128)])
def test_rmsnorm(shape, dtype):
    ks = jax.random.split(KEY, 2)
    x = jax.random.normal(ks[0], shape, dtype)
    w = jax.random.normal(ks[1], (shape[-1],), jnp.float32)
    out = ops.rmsnorm(x, w)
    gold = ref.rmsnorm_ref(x, w)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(gold, np.float32), **_tol(dtype))


@pytest.mark.parametrize("M,N,D", [(16, 16, 8), (32, 48, 16), (64, 30, 32)])
def test_top1_similarity(M, N, D):
    ks = jax.random.split(KEY, 2)
    e1 = jax.random.normal(ks[0], (M, D))
    e2 = jax.random.normal(ks[1], (N, D))
    e1 = e1 / jnp.linalg.norm(e1, axis=1, keepdims=True)
    e2 = e2 / jnp.linalg.norm(e2, axis=1, keepdims=True)
    i1, s1 = ops.top1_similarity(e1, e2)
    i2, s2 = ref.top1_sim_ref(e1, e2)
    assert bool(jnp.all(i1 == i2))
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2), rtol=1e-5, atol=1e-5)


def _lattice(key, shape):
    """Quarter-integer entries in [-1, 1]: every dot product is exactly
    representable in f32, so blocked and dense contractions round
    identically (bit-parity is testable) and duplicated rows are *true*
    ties (the tie-break order is testable)."""
    return jax.random.randint(key, shape, -4, 5).astype(jnp.float32) / 4.0


def _topk_all(e1, e2, k):
    """(kernel, XLA fallback, reference) results for one input."""
    from repro.models import layers as L

    return (ops.topk_similarity(e1, e2, k=k),
            L.topk_similarity(e1, e2, k),
            ref.topk_sim_ref(e1, e2, k))


def _assert_topk_exact(e1, e2, k):
    (ki, ksim), (fi, fsim), (gi, gsim) = _topk_all(e1, e2, k)
    k_eff = min(k, e2.shape[0])
    assert ki.shape == fi.shape == gi.shape == (e1.shape[0], k_eff)
    assert bool(jnp.all(ki == gi)) and bool(jnp.all(fi == gi))
    # bit-exact on lattice inputs, kernel AND fallback
    assert bool(jnp.all(ksim == gsim)) and bool(jnp.all(fsim == gsim))


# prime/ragged shapes exercise the padding path (the old block-shrink
# loops degenerated to 1-wide blocks on prime extents); (1, 7, 8) pins
# the M=1 contraction layout; (257, 259, 8) spans multiple 256-blocks
@pytest.mark.parametrize("M,N,D", [
    (16, 16, 8), (32, 48, 16), (64, 30, 32),
    (17, 13, 8), (31, 29, 16), (97, 101, 24),
    (257, 259, 8), (5, 3, 4), (1, 7, 8),
])
@pytest.mark.parametrize("k", [1, 4, 16])
def test_topk_similarity_exact(M, N, D, k):
    ks = jax.random.split(KEY, 2)
    _assert_topk_exact(_lattice(ks[0], (M, D)), _lattice(ks[1], (N, D)), k)


@pytest.mark.parametrize("M,N,D,k", [
    (5, 3, 4, 25), (31, 29, 16, 1000), (16, 16, 8, 16),
])
def test_topk_k_exceeds_n(M, N, D, k):
    """k >= N returns exactly N columns: a full similarity argsort."""
    ks = jax.random.split(KEY, 2)
    _assert_topk_exact(_lattice(ks[0], (M, D)), _lattice(ks[1], (N, D)), k)


@pytest.mark.parametrize("M", [1, 33])
@pytest.mark.parametrize("k", [1, 3, 8, 25, 40])
def test_topk_ties_break_to_lower_index(M, k):
    """Duplicated e2 rows are exact ties on lattice inputs; the kernel
    must order them lower-index-first, matching ``jax.lax.top_k``."""
    ks = jax.random.split(KEY, 2)
    e1 = _lattice(ks[0], (M, 8))
    base = _lattice(ks[1], (5, 8))
    e2 = jnp.tile(base, (5, 1))  # 25 rows, each one of 5 distinct vectors
    _assert_topk_exact(e1, e2, k)


def test_topk_normalized_gaussian():
    """Continuous inputs: indices still agree exactly (no measure-zero
    ties), similarities to float tolerance."""
    ks = jax.random.split(KEY, 2)
    e1 = jax.random.normal(ks[0], (64, 32))
    e2 = jax.random.normal(ks[1], (50, 32))
    e1 = e1 / jnp.linalg.norm(e1, axis=1, keepdims=True)
    e2 = e2 / jnp.linalg.norm(e2, axis=1, keepdims=True)
    (ki, ksim), (fi, fsim), (gi, gsim) = _topk_all(e1, e2, 8)
    assert bool(jnp.all(ki == gi)) and bool(jnp.all(fi == gi))
    np.testing.assert_allclose(np.asarray(ksim), np.asarray(gsim), atol=1e-6)
    np.testing.assert_allclose(np.asarray(fsim), np.asarray(gsim), atol=1e-6)


def test_top1_is_topk_column_zero():
    ks = jax.random.split(KEY, 2)
    e1, e2 = _lattice(ks[0], (31, 16)), _lattice(ks[1], (29, 16))
    i1, s1 = ops.top1_similarity(e1, e2)
    ik, sk = ops.topk_similarity(e1, e2, k=1)
    assert bool(jnp.all(i1 == ik[:, 0])) and bool(jnp.all(s1 == sk[:, 0]))


def test_topk_rejects_bad_k():
    from repro.kernels import topk_sim

    e = jnp.ones((4, 4), jnp.float32)
    with pytest.raises(ValueError):
        topk_sim.topk_similarity(e, e, 0)


def test_flash_attention_inside_model():
    """cfg.use_pallas routes the model's attention through the kernel."""
    import dataclasses

    from repro.configs import get_smoke_config
    from repro.models import forward, init_params, model_specs

    cfg = get_smoke_config("yi-9b")
    params = init_params(model_specs(cfg), KEY, jnp.float32)
    batch = {"tokens": jax.random.randint(KEY, (2, 32), 0, cfg.vocab_size)}
    logits_xla, _ = forward(cfg, params, batch)
    cfg_k = dataclasses.replace(cfg, use_pallas=True)
    logits_pl, _ = forward(cfg_k, params, batch)
    np.testing.assert_allclose(np.asarray(logits_xla), np.asarray(logits_pl),
                               rtol=1e-4, atol=1e-4)


def test_ssd_kernel_inside_model():
    import dataclasses

    from repro.configs import get_smoke_config
    from repro.models import forward, init_params, model_specs

    cfg = get_smoke_config("mamba2-130m")
    params = init_params(model_specs(cfg), KEY, jnp.float32)
    batch = {"tokens": jax.random.randint(KEY, (2, 32), 0, cfg.vocab_size)}
    logits_xla, _ = forward(cfg, params, batch)
    cfg_k = dataclasses.replace(cfg, use_pallas=True)
    logits_pl, _ = forward(cfg_k, params, batch)
    np.testing.assert_allclose(np.asarray(logits_xla), np.asarray(logits_pl),
                               rtol=1e-3, atol=1e-3)
