"""Per-kernel allclose sweeps vs the pure-jnp oracles (interpret mode)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

KEY = jax.random.PRNGKey(42)


def _tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 else dict(rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "B,S,H,KV,hd,chunk",
    [
        (1, 32, 2, 2, 16, 16),     # MHA
        (2, 64, 4, 2, 32, 16),     # GQA 2:1
        (1, 96, 8, 2, 16, 32),     # GQA 4:1, S not a power of two
        (2, 64, 6, 3, 8, 16),      # odd head count (starcoder-like)
        (1, 128, 4, 1, 64, 64),    # MQA, big head_dim
    ],
)
def test_flash_attention(B, S, H, KV, hd, chunk, dtype):
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, S, H, hd), dtype)
    k = jax.random.normal(ks[1], (B, S, KV, hd), dtype)
    v = jax.random.normal(ks[2], (B, S, KV, hd), dtype)
    out = ops.flash_attention(q, k, v, chunk=chunk)
    gold = ref.flash_attention_ref(q, k, v)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(gold, np.float32), **_tol(dtype))


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "B,Skv,H,KV,hd",
    [(1, 32, 2, 2, 16), (2, 64, 4, 2, 32), (3, 48, 8, 2, 16), (2, 128, 4, 1, 64)],
)
def test_decode_attention(B, Skv, H, KV, hd, dtype):
    ks = jax.random.split(KEY, 4)
    q = jax.random.normal(ks[0], (B, 1, H, hd), dtype)
    kc = jax.random.normal(ks[1], (B, Skv, KV, hd), dtype)
    vc = jax.random.normal(ks[2], (B, Skv, KV, hd), dtype)
    clen = jax.random.randint(ks[3], (B,), 1, Skv + 1)
    out = ops.decode_attention(q, kc, vc, clen)
    gold = ref.decode_attention_ref(q, kc, vc, clen)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(gold, np.float32), **_tol(dtype))


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "B,S,H,P,N,chunk",
    [(1, 32, 2, 8, 4, 8), (2, 64, 3, 16, 8, 16), (1, 48, 4, 8, 16, 12)],
)
def test_ssd_scan(B, S, H, P, N, chunk, dtype):
    ks = jax.random.split(KEY, 5)
    x = jax.random.normal(ks[0], (B, S, H, P), dtype)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, H), jnp.float32))
    A = -jnp.exp(jax.random.normal(ks[2], (H,), jnp.float32) * 0.5)
    b = jax.random.normal(ks[3], (B, S, N), dtype)
    c = jax.random.normal(ks[4], (B, S, N), dtype)
    out = ops.ssd_scan(x, dt, A, b, c, chunk=chunk)
    gold = ref.ssd_scan_ref(x, dt, A, b, c)
    tol = dict(rtol=5e-2, atol=5e-2) if dtype == jnp.bfloat16 else dict(rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(gold, np.float32), **tol)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("shape", [(8, 32), (4, 33, 64), (2, 5, 7, 128)])
def test_rmsnorm(shape, dtype):
    ks = jax.random.split(KEY, 2)
    x = jax.random.normal(ks[0], shape, dtype)
    w = jax.random.normal(ks[1], (shape[-1],), jnp.float32)
    out = ops.rmsnorm(x, w)
    gold = ref.rmsnorm_ref(x, w)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(gold, np.float32), **_tol(dtype))


@pytest.mark.parametrize("M,N,D", [(16, 16, 8), (32, 48, 16), (64, 30, 32)])
def test_top1_similarity(M, N, D):
    ks = jax.random.split(KEY, 2)
    e1 = jax.random.normal(ks[0], (M, D))
    e2 = jax.random.normal(ks[1], (N, D))
    e1 = e1 / jnp.linalg.norm(e1, axis=1, keepdims=True)
    e2 = e2 / jnp.linalg.norm(e2, axis=1, keepdims=True)
    i1, s1 = ops.top1_similarity(e1, e2)
    i2, s2 = ref.top1_sim_ref(e1, e2)
    assert bool(jnp.all(i1 == i2))
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2), rtol=1e-5, atol=1e-5)


def test_flash_attention_inside_model():
    """cfg.use_pallas routes the model's attention through the kernel."""
    import dataclasses

    from repro.configs import get_smoke_config
    from repro.models import forward, init_params, model_specs

    cfg = get_smoke_config("yi-9b")
    params = init_params(model_specs(cfg), KEY, jnp.float32)
    batch = {"tokens": jax.random.randint(KEY, (2, 32), 0, cfg.vocab_size)}
    logits_xla, _ = forward(cfg, params, batch)
    cfg_k = dataclasses.replace(cfg, use_pallas=True)
    logits_pl, _ = forward(cfg_k, params, batch)
    np.testing.assert_allclose(np.asarray(logits_xla), np.asarray(logits_pl),
                               rtol=1e-4, atol=1e-4)


def test_ssd_kernel_inside_model():
    import dataclasses

    from repro.configs import get_smoke_config
    from repro.models import forward, init_params, model_specs

    cfg = get_smoke_config("mamba2-130m")
    params = init_params(model_specs(cfg), KEY, jnp.float32)
    batch = {"tokens": jax.random.randint(KEY, (2, 32), 0, cfg.vocab_size)}
    logits_xla, _ = forward(cfg, params, batch)
    cfg_k = dataclasses.replace(cfg, use_pallas=True)
    logits_pl, _ = forward(cfg_k, params, batch)
    np.testing.assert_allclose(np.asarray(logits_xla), np.asarray(logits_pl),
                               rtol=1e-3, atol=1e-3)
