"""Training substrate: optimizer correctness, accumulation equivalence,
checkpoint round-trips, trainer crash-resume."""

import shutil
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import latest_step, restore, save
from repro.configs import get_smoke_config
from repro.train.optimizer import (
    AdamWConfig,
    adamw_init,
    adamw_update,
    clip_by_global_norm,
    cosine_schedule,
    global_norm,
)
from repro.train.train_step import make_train_state, train_step

KEY = jax.random.PRNGKey(0)


def test_adamw_matches_reference_formula():
    """One step of our AdamW vs the textbook update, elementwise."""
    cfg = AdamWConfig(lr=1e-2, b1=0.9, b2=0.99, eps=1e-8,
                      weight_decay=0.1, clip_norm=1e9)
    p = {"w": jnp.array([1.0, -2.0, 3.0])}
    g = {"w": jnp.array([0.1, 0.2, -0.3])}
    state = adamw_init(p, cfg)
    new_p, new_state, _ = adamw_update(g, state, p, cfg, lr=1e-2)

    m = 0.1 * np.array([0.1, 0.2, -0.3])
    v = 0.01 * np.array([0.1, 0.2, -0.3]) ** 2
    mhat = m / (1 - 0.9)
    vhat = v / (1 - 0.99)
    want = (np.array([1.0, -2.0, 3.0])
            - 1e-2 * (mhat / (np.sqrt(vhat) + 1e-8)
                      + 0.1 * np.array([1.0, -2.0, 3.0])))
    np.testing.assert_allclose(np.asarray(new_p["w"]), want, rtol=1e-6)
    assert int(new_state["count"]) == 1


def test_clip_by_global_norm():
    tree = {"a": jnp.ones((4,)) * 3.0, "b": jnp.ones((4,)) * 4.0}
    clipped, norm = clip_by_global_norm(tree, 1.0)
    assert norm == pytest.approx(10.0)
    assert global_norm(clipped) == pytest.approx(1.0, rel=1e-5)


def test_cosine_schedule_shape():
    lr0 = float(cosine_schedule(0, peak_lr=1.0, warmup=10, total=100))
    lr_w = float(cosine_schedule(10, peak_lr=1.0, warmup=10, total=100))
    lr_end = float(cosine_schedule(100, peak_lr=1.0, warmup=10, total=100))
    assert lr0 == 0.0 and lr_w == pytest.approx(1.0)
    assert lr_end == pytest.approx(0.1, rel=1e-5)  # floor_frac


def test_accumulation_equivalence():
    """accum_steps=2 must produce (numerically) the same update as 1."""
    cfg = get_smoke_config("yi-9b")
    batch = {"tokens": jax.random.randint(KEY, (4, 32), 0, cfg.vocab_size)}
    s1 = make_train_state(cfg, KEY, dtype=jnp.float32)
    s2 = make_train_state(cfg, KEY, dtype=jnp.float32)
    s1, m1 = train_step(cfg, s1, batch, accum_steps=1)
    s2, m2 = train_step(cfg, s2, batch, accum_steps=2)
    assert m1["loss"] == pytest.approx(m2["loss"], rel=1e-5)
    d = jax.tree.map(lambda a, b: float(jnp.max(jnp.abs(a - b))),
                     s1.params, s2.params)
    assert max(jax.tree.leaves(d)) < 1e-5


def test_bf16_optimizer_state_still_converges():
    cfg = get_smoke_config("granite-3-2b")
    ocfg = AdamWConfig(state_dtype=jnp.bfloat16)
    state = make_train_state(cfg, KEY, dtype=jnp.float32, opt_cfg=ocfg)
    batch = {"tokens": jax.random.randint(KEY, (2, 32), 0, cfg.vocab_size)}
    losses = []
    step = jax.jit(lambda s, b: train_step(cfg, s, b, opt_cfg=ocfg))
    for _ in range(5):
        state, m = step(state, batch)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0]
    assert state.opt["m"]["final_norm"].dtype == jnp.bfloat16


# ---------------------------------------------------------------------------
# checkpointing
# ---------------------------------------------------------------------------


def test_checkpoint_roundtrip_and_commit_protocol():
    d = tempfile.mkdtemp()
    try:
        tree = {"a": jnp.arange(12.0).reshape(3, 4),
                "nested": {"b": jnp.ones((2,), jnp.int32)}}
        save(d, 5, tree)
        assert latest_step(d) == 5
        out = restore(d, tree)
        np.testing.assert_array_equal(np.asarray(out["a"]), np.asarray(tree["a"]))
        assert out["nested"]["b"].dtype == jnp.int32

        # uncommitted checkpoint (no COMMIT marker) must be ignored
        import os

        os.makedirs(os.path.join(d, "step_9"), exist_ok=True)
        assert latest_step(d) == 5
    finally:
        shutil.rmtree(d)


def test_trainer_crash_resume():
    from repro.train.trainer import SimulatedNodeFailure, Trainer, TrainerConfig

    cfg = get_smoke_config("mamba2-130m")
    d = tempfile.mkdtemp()

    def batch_fn(step):
        rng = np.random.default_rng(np.random.SeedSequence([0, step]))
        return {"tokens": rng.integers(0, cfg.vocab_size, size=(2, 32),
                                       dtype=np.int32)}

    try:
        tcfg = TrainerConfig(total_steps=8, checkpoint_every=3,
                             checkpoint_dir=d, fail_at_step=5, log_every=100)
        with pytest.raises(SimulatedNodeFailure):
            Trainer(cfg, tcfg, batch_fn).run()
        assert latest_step(d) == 3
        tcfg2 = TrainerConfig(total_steps=8, checkpoint_every=3,
                              checkpoint_dir=d, log_every=100)
        state = Trainer(cfg, tcfg2, batch_fn).run()
        assert int(state.step) == 8
    finally:
        shutil.rmtree(d)
