"""Executor-layer tests that need no hosted model: incremental stop
matching, lazy submission handles, and overflow cancellation through the
join operators (DESIGN.md §8)."""

import pytest

from repro.core import block_join, tuple_join
from repro.core.join_types import Overflow
from repro.core.llm_client import LLMClient, LLMResponse
from repro.core.oracle import OracleLLM
from repro.core.accounting import Usage
from repro.serve.engine import StopMatcher


# ---------------------------------------------------------------------------
# StopMatcher — O(1) incremental `text.rstrip().endswith(stop)`
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("pieces,stop,expect", [
    (["1,2; ", "Fin", "ished"], "Finished", [False, False, True]),
    (["Finis", "hed", "  \n"], "Finished", [False, True, True]),
    (["Fi", "nished", " no"], "Finished", [False, True, False]),
    (["x", "END"], "END", [False, True]),
    (["EN", "Dmore"], "END", [False, False]),
])
def test_stop_matcher_matches_full_decode(pieces, stop, expect):
    m = StopMatcher(stop)
    text = ""
    for piece, want in zip(pieces, expect):
        text += piece
        got = m.push(piece)
        assert got == text.rstrip().endswith(stop)
        assert got == want


def test_stop_matcher_constant_state_under_long_generation():
    m = StopMatcher("Finished")
    for _ in range(10_000):
        m.push("ab")
    assert len(m._tail) <= len("Finished")
    assert m.push(" Finished")


def test_stop_matcher_bounded_on_whitespace_runs():
    """A degenerate all-whitespace generation must not grow matcher state
    (push stays O(1)); matching across the run still agrees with the
    full-text check."""
    m = StopMatcher("END")
    text = "x"
    m.push("x")
    for _ in range(5_000):
        text += "\n"
        m.push("\n")
    assert len(m._pending) <= len("END")
    text += "END"
    assert m.push("END") == text.rstrip().endswith("END") == True


def test_stop_matcher_none_never_matches():
    m = StopMatcher(None)
    assert not m.push("anything Finished")


# ---------------------------------------------------------------------------
# Lazy submission surface of the base LLMClient
# ---------------------------------------------------------------------------

class CountingClient(LLMClient):
    """Minimal sequential client that counts real invocations."""

    context_limit = 8192

    def __init__(self):
        self.invocations = 0

    def invoke(self, prompt, *, max_tokens, stop=None):
        self.invocations += 1
        return LLMResponse("Yes", Usage(self.count_tokens(prompt), 1), "stop")


def test_cancelled_handles_are_never_invoked():
    c = CountingClient()
    handles = [c.submit(f"p{i}", max_tokens=4) for i in range(5)]
    handles[2].cancel()
    handles[4].cancel()
    done = list(c.as_completed(handles))
    assert c.invocations == 3
    assert len(done) == 3
    with pytest.raises(RuntimeError):
        handles[2].result()


def test_invoke_many_on_submission_surface():
    c = CountingClient()
    out = c.invoke_many(["a", "b", "c"], max_tokens=1)
    assert [r.text for r in out] == ["Yes"] * 3
    assert c.invocations == 3


# ---------------------------------------------------------------------------
# Overflow cancellation through the block join (cheap adaptive restarts)
# ---------------------------------------------------------------------------

def test_block_join_overflow_cancels_queued_blocks():
    """On the first incomplete answer, blocks still queued behind it are
    cancelled and never paid for — the ledger must show strictly fewer
    calls than the number of blocks."""
    r1 = [f"item {i}" for i in range(8)]
    r2 = ["item 0"]
    # every pair matches → the 1x1 block prompt (73 word-tokens) fits, but
    # its answer "1,1; Finished" (5 tokens) does not — truncated mid-answer
    oracle = OracleLLM(lambda a, b: True, context_limit=76)
    n_blocks = 8  # b1=1, b2=1 → 8 blocks
    with pytest.raises(Overflow):
        block_join(r1, r2, "always", oracle, 1, 1)
    # ledger travels inside the Overflow; re-run with an explicit one
    from repro.core.accounting import Ledger
    ledger = Ledger()
    with pytest.raises(Overflow):
        block_join(r1, r2, "always", oracle, 1, 1, ledger=ledger)
    assert ledger.calls < n_blocks
    assert ledger.overflows >= 1


def test_block_join_completed_blocks_not_repaid():
    """The resume memo skips already-solved blocks entirely."""
    from repro.core.accounting import Ledger

    r1 = [f"item {i % 3}" for i in range(6)]
    r2 = [f"item {i % 3}" for i in range(6)]
    pred = lambda a, b: a == b
    full_ledger = Ledger()
    full = block_join(r1, r2, "equal", OracleLLM(pred), 2, 2,
                      completed={}, ledger=full_ledger)
    memo = {}
    res = block_join(r1, r2, "equal", OracleLLM(pred), 2, 2, completed=memo)
    # replay with half the blocks already solved
    partial = {k: memo[k] for k in list(memo)[: len(memo) // 2]}
    replay_ledger = Ledger()
    replay = block_join(r1, r2, "equal", OracleLLM(pred), 2, 2,
                        completed=partial, ledger=replay_ledger)
    assert replay.pairs == full.pairs == res.pairs
    assert replay_ledger.calls == full_ledger.calls - len(memo) // 2


def test_covered_requires_single_rectangle():
    """Pin the resume memo's conservative containment rule: a rect covered
    only by the UNION of solved rectangles is re-executed.

    Each memo entry certifies one *complete* block answer under one call's
    token budget; two half-rect answers certify nothing about the combined
    block's own answer fitting, so `_covered` deliberately refuses union
    coverage (see its docstring).  This test fails loudly if someone
    "optimizes" it into a union check.
    """
    from repro.core.block_join import _covered

    completed = {(0, 2, 0, 2): set(), (2, 4, 0, 2): set()}
    # union of the two solved rects tiles (0,4,0,2) exactly — still no
    assert not _covered((0, 4, 0, 2), completed)
    # single-rectangle containment (equal or strictly inside) is accepted
    assert _covered((0, 2, 0, 2), completed)
    assert _covered((2, 3, 0, 1), completed)
    # overlap without containment is rejected
    assert not _covered((1, 3, 0, 2), completed)
    assert not _covered((0, 2, 0, 3), completed)


def test_block_join_repays_union_covered_blocks():
    """Behavioral pin of the conservative `_covered`: a memo holding two
    half-blocks that tile a full block does NOT suppress the full block's
    call."""
    from repro.core.accounting import Ledger

    r1 = [f"item {i}" for i in range(4)]
    r2 = ["item 0", "item 1"]
    pred = lambda a, b: a == b
    # memo from a b1=2 run: two rects tiling r1 × r2
    memo = {}
    block_join(r1, r2, "equal", OracleLLM(pred), 2, 2, completed=memo)
    assert set(memo) == {(0, 2, 0, 2), (2, 4, 0, 2)}
    # a b1=4 retry re-pays its single (union-covered) block
    ledger = Ledger()
    res = block_join(r1, r2, "equal", OracleLLM(pred), 4, 2,
                     completed=dict(memo), ledger=ledger)
    assert ledger.calls == 1
    assert res.pairs == {(0, 0), (1, 1)}


def test_tuple_join_on_submission_surface():
    r1, r2 = ["a", "b"], ["b", "a"]
    res = tuple_join(r1, r2, "equal", OracleLLM(lambda a, b: a == b))
    assert res.pairs == {(0, 1), (1, 0)}
    assert res.ledger.calls == 4
