"""Property-based tests (hypothesis) for the paper's cost model & theory.

Each test verifies one lemma/theorem of §3–§6 over randomized parameter
space, not just the paper's worked examples.
"""

import math

import pytest
pytest.importorskip("hypothesis")  # dev-only dep; see requirements-dev.txt
from hypothesis import given, settings, strategies as st

from repro.core.batch_opt import (
    InfeasibleBudget,
    optimal_b1_continuous,
    optimal_b2_continuous,
    optimal_batch_sizes,
)
from repro.core.cost_model import (
    JoinStats,
    b2_on_boundary,
    block_join_cost,
    budget_lhs,
    c_star,
    cost_per_call,
    num_calls,
    tokens_per_call,
    tuple_join_cost,
)

sizes = st.floats(min_value=1.0, max_value=200.0)
sigmas = st.floats(min_value=1e-5, max_value=1.0)
budgets = st.floats(min_value=500.0, max_value=16384.0)


def make_stats(s1, s2, s3, p=50.0, r1=1000, r2=800):
    return JoinStats(r1=r1, r2=r2, s1=s1, s2=s2, s3=s3, p=p)


# ---------------------------------------------------------------------------
# §3/§4 formulas
# ---------------------------------------------------------------------------


def test_tuple_cost_corollary_3_2():
    stats = JoinStats(r1=10, r2=20, s1=30, s2=40, s3=2, p=50)
    assert tuple_join_cost(stats, g=2.0) == 10 * 20 * (50 + 30 + 40 + 2)


@given(sizes, sizes, st.floats(1.0, 8.0), sigmas)
@settings(max_examples=50, deadline=None)
def test_lemma_4_1_4_2_4_3(s1, s2, s3, sigma):
    stats = make_stats(s1, s2, s3)
    b1, b2 = 7, 13
    toks = tokens_per_call(b1, b2, stats, sigma)
    assert toks == pytest.approx(stats.p + b1 * s1 + b2 * s2 + b1 * b2 * sigma * s3)
    cost = cost_per_call(b1, b2, stats, sigma, g=3.0)
    assert cost == pytest.approx(
        stats.p + b1 * s1 + b2 * s2 + b1 * b2 * sigma * s3 * 3.0)
    assert num_calls(b1, b2, stats) == pytest.approx(
        (stats.r1 / b1) * (stats.r2 / b2))
    assert block_join_cost(b1, b2, stats, sigma, 3.0) == pytest.approx(
        num_calls(b1, b2, stats) * cost)


# ---------------------------------------------------------------------------
# Theorem 5.2 — cost minimized on the budget boundary
# ---------------------------------------------------------------------------


@given(sizes, sizes, st.floats(1.0, 8.0), sigmas, budgets,
       st.floats(1.05, 3.0))
@settings(max_examples=50, deadline=None)
def test_theorem_5_2_scaling_up_never_hurts(s1, s2, s3, sigma, t, alpha):
    stats = make_stats(s1, s2, s3)
    b1, b2 = 3.0, 5.0
    if budget_lhs(b1 * alpha, b2, stats, sigma) > t:
        return  # scaled point infeasible — theorem precondition unmet
    c_small = block_join_cost(b1, b2, stats, sigma, 1.0)
    c_big = block_join_cost(b1 * alpha, b2, stats, sigma, 1.0)
    assert c_big <= c_small * (1 + 1e-9)


# ---------------------------------------------------------------------------
# Lemma 5.4 — b2(b1) lies exactly on the boundary
# ---------------------------------------------------------------------------


@given(sizes, sizes, st.floats(1.0, 8.0), sigmas, budgets)
@settings(max_examples=50, deadline=None)
def test_lemma_5_4_boundary(s1, s2, s3, sigma, t):
    stats = make_stats(s1, s2, s3)
    b1 = min(3.0, t / (2 * s1))
    b2 = b2_on_boundary(b1, stats, sigma, t)
    if b2 <= 0:
        return
    assert budget_lhs(b1, b2, stats, sigma) == pytest.approx(t, rel=1e-9)


# ---------------------------------------------------------------------------
# Theorem 5.6 — the closed form minimizes c*(b1)
# ---------------------------------------------------------------------------


@given(sizes, sizes, st.floats(1.0, 8.0), sigmas, budgets)
@settings(max_examples=50, deadline=None)
def test_theorem_5_6_closed_form_is_minimum(s1, s2, s3, sigma, t):
    stats = make_stats(s1, s2, s3)
    b1_star = optimal_b1_continuous(s1, s2, s3, sigma, t)
    if not (0 < b1_star and b1_star * s1 < t):
        return
    c_opt = c_star(b1_star, stats, sigma, 1.0, t)
    for mult in (0.5, 0.8, 1.25, 2.0):
        b1 = b1_star * mult
        if not (0 < b1 and b1 * s1 < t and
                b2_on_boundary(b1, stats, sigma, t) > 0):
            continue
        assert c_star(b1, stats, sigma, 1.0, t) >= c_opt * (1 - 1e-9)


# ---------------------------------------------------------------------------
# Integer optimizer == exhaustive grid argmin
# ---------------------------------------------------------------------------


@given(st.integers(2, 40), st.integers(2, 40), st.integers(1, 4),
       st.floats(0.001, 1.0), st.integers(200, 2000))
@settings(max_examples=40, deadline=None)
def test_integer_optimizer_matches_grid(s1, s2, s3, sigma, t):
    stats = JoinStats(r1=60, r2=40, s1=s1, s2=s2, s3=s3, p=10)
    try:
        b1, b2 = optimal_batch_sizes(stats, sigma, t)
    except InfeasibleBudget:
        assert s1 + s2 + s3 * sigma > t
        return
    assert budget_lhs(b1, b2, stats, sigma) <= t + 1e-9

    def true_cost(bb1, bb2):
        calls = math.ceil(stats.r1 / bb1) * math.ceil(stats.r2 / bb2)
        return calls * cost_per_call(bb1, bb2, stats, sigma, 1.0)

    best = min(
        (true_cost(bb1, bb2)
         for bb1 in range(1, 61) for bb2 in range(1, 41)
         if budget_lhs(bb1, bb2, stats, sigma) <= t),
        default=None,
    )
    assert best is not None
    assert true_cost(b1, b2) <= best * 1.02  # within 2% of the grid optimum


# ---------------------------------------------------------------------------
# Lemma 6.2 — b1*(σ) anti-monotone; Lemma 6.3/6.4 bounds; Theorem 6.5
# ---------------------------------------------------------------------------


@given(sizes, sizes, st.floats(1.0, 8.0), budgets,
       st.floats(1e-4, 0.5), st.floats(1.1, 8.0))
@settings(max_examples=50, deadline=None)
def test_lemma_6_2_antimonotone(s1, s2, s3, t, sigma, factor):
    lo = optimal_b1_continuous(s1, s2, s3, sigma, t)
    hi = optimal_b1_continuous(s1, s2, s3, min(sigma * factor, 1.0), t)
    assert hi <= lo + 1e-9


@given(sizes, sizes, st.floats(1.0, 8.0), budgets,
       st.floats(1e-4, 0.25), st.floats(1.1, 4.0))
@settings(max_examples=50, deadline=None)
def test_lemma_6_3_6_4(s1, s2, s3, t, e_over_alpha, alpha):
    e = min(e_over_alpha * alpha, 1.0)
    sigma = e_over_alpha  # σ = e/α ≤ σ ≤ e boundary case
    b1_sigma = optimal_b1_continuous(s1, s2, s3, sigma, t)
    b1_e = optimal_b1_continuous(s1, s2, s3, e, t)
    if b1_sigma * s1 >= t or b1_e * s1 >= t:
        return
    assert b1_sigma <= alpha * b1_e + 1e-6  # Lemma 6.3
    b2_sigma = optimal_b2_continuous(b1_sigma, s1, s2, s3, sigma, t)
    b2_e = optimal_b2_continuous(b1_e, s1, s2, s3, e, t)
    if b2_sigma <= 0 or b2_e <= 0:
        return
    assert b1_sigma * b2_sigma <= alpha * b1_e * b2_e * (1 + 1e-6)  # Lemma 6.4


@given(sizes, sizes, st.floats(1.0, 8.0), budgets,
       st.floats(1e-4, 0.25), st.floats(1.1, 4.0), st.floats(1.0, 3.0))
@settings(max_examples=50, deadline=None)
def test_theorem_6_5_cost_bound(s1, s2, s3, t, sigma, alpha, g):
    """o(e, σ) ≤ α·g·o(σ, σ) for e ∈ [σ, α·σ]."""
    e = min(sigma * alpha, 1.0)
    stats = make_stats(s1, s2, s3)
    b1_e = optimal_b1_continuous(s1, s2, s3, e, t)
    b1_s = optimal_b1_continuous(s1, s2, s3, sigma, t)
    if b1_e * s1 >= t or b1_s * s1 >= t:
        return
    b2_e = optimal_b2_continuous(b1_e, s1, s2, s3, e, t)
    b2_s = optimal_b2_continuous(b1_s, s1, s2, s3, sigma, t)
    if b2_e <= 0 or b2_s <= 0:
        return
    # cost with batch sizes tuned for e, actual selectivity σ
    o_e = block_join_cost(b1_e, b2_e, stats, sigma, g)
    o_s = block_join_cost(b1_s, b2_s, stats, sigma, g)
    assert o_e <= alpha * g * o_s * (1 + 1e-6)
