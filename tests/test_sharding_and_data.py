"""Sharding rules, tokenizer round-trips, loader determinism."""

import subprocess
import sys

import jax
import numpy as np
import pytest
pytest.importorskip("hypothesis")  # dev-only dep; see requirements-dev.txt
from hypothesis import given, settings, strategies as st
from jax.sharding import PartitionSpec as P

from repro.data.loader import Prefetcher, pack_documents, synthetic_lm_batches
from repro.data.tokenizer import ByteTokenizer
from repro.sharding.logical import MeshContext, DEFAULT_RULES


class FakeDevices:
    shape = (4, 4)


class FakeMesh:
    axis_names = ("data", "model")
    devices = FakeDevices()


def _resolve(axes, rules=None):
    merged = dict(DEFAULT_RULES)
    merged.update(rules or {})
    ctx = MeshContext.__new__(MeshContext)
    ctx.mesh = FakeMesh()
    ctx.rules = merged
    return ctx.resolve(axes)


def test_rules_resolution_basics():
    assert _resolve(("batch", "seq", "embed")) == P("data", None, None)
    assert _resolve(("embed_fsdp", "mlp")) == P("data", "model")
    assert _resolve(("vocab", "embed")) == P("model", None)


def test_rules_drop_missing_mesh_axes():
    # "pod" doesn't exist on the single-pod mesh → silently dropped
    assert _resolve(("batch",)) == P("data")


def test_rules_never_reuse_a_mesh_axis():
    # both logical axes map to "model": the second use must be dropped
    spec = _resolve(("heads", "mlp"))
    used = [s for s in spec if s is not None]
    assert used.count("model") <= 1


def test_per_arch_overrides():
    spec = _resolve(("experts", "embed_fsdp", "expert_mlp"),
                    rules={"experts": None, "expert_mlp": "model"})
    assert spec == P(None, "data", "model")


def test_grok_overrides_merge_over_default_rules():
    """use_mesh(mesh, cfg.rules()) merges per-arch overrides on top of
    DEFAULT_RULES: grok moves `experts` off "model" and puts `expert_mlp`
    on it (8 experts can't tile a wide TP axis), while untouched defaults
    (heads → "model") survive the merge."""
    from repro.configs import get_smoke_config
    from repro.sharding.logical import mesh_active, use_mesh

    am = jax.sharding.AbstractMesh((("model", 32),))
    grok_rules = get_smoke_config("grok-1-314b").rules()
    assert grok_rules == {"experts": None, "expert_mlp": "model"}
    assert not mesh_active()
    with use_mesh(am, grok_rules) as ctx:
        assert mesh_active()
        assert ctx.rules["experts"] is None
        assert ctx.rules["expert_mlp"] == "model"
        assert ctx.rules["heads"] == "model"  # default retained
        spec = ctx.resolve(("experts", "expert_mlp"), (8, 32768))
        assert spec == P(None, "model")
    assert not mesh_active()


def test_shard_is_noop_outside_mesh():
    from repro.sharding.logical import shard, use_mesh

    x = jax.numpy.ones((4, 8))
    assert shard(x, "batch", "embed") is x
    with pytest.raises(ValueError, match="rank mismatch"):
        with use_mesh(jax.sharding.AbstractMesh((("model", 2),))):
            shard(x, "batch")


def test_abstract_mesh_resolution_matches_fake_mesh():
    """AbstractMesh exposes .shape as a name→size Mapping (no .devices);
    MeshContext.resolve must agree with the devices-backed path on both
    plain resolution and divisibility-driven axis dropping."""
    am = jax.sharding.AbstractMesh((("data", 4), ("model", 4)))
    ctx = MeshContext(mesh=am, rules=dict(DEFAULT_RULES))
    for axes in [("batch", "seq", "embed"), ("embed_fsdp", "mlp"),
                 ("vocab", "embed")]:
        assert ctx.resolve(axes) == _resolve(axes)
    # 36 heads tile a 4-way axis; 30 don't → dropped to replication,
    # identically on both paths
    assert ctx.resolve(("heads",), (36,)) == P("model")
    assert ctx.resolve(("heads",), (30,)) == P(None)
    for n in (36, 30):
        assert ctx.resolve(("heads",), (n,)) == _resolve_shaped(("heads",), (n,))


def _resolve_shaped(axes, shape, rules=None):
    merged = dict(DEFAULT_RULES)
    merged.update(rules or {})
    ctx = MeshContext.__new__(MeshContext)
    ctx.mesh = FakeMesh()
    ctx.rules = merged
    return ctx.resolve(axes, shape)


# ---------------------------------------------------------------------------
# tokenizer / loader
# ---------------------------------------------------------------------------


@given(st.text(max_size=200))
@settings(max_examples=100, deadline=None)
def test_byte_tokenizer_roundtrip(text):
    tok = ByteTokenizer(512)
    assert tok.decode(tok.encode(text)) == text


def test_synthetic_batches_deterministic_and_resumable():
    a = synthetic_lm_batches(1000, 4, 16, seed=7)
    b = synthetic_lm_batches(1000, 4, 16, seed=7)
    first_a = [next(a) for _ in range(3)]
    first_b = [next(b) for _ in range(3)]
    for x, y in zip(first_a, first_b):
        np.testing.assert_array_equal(x, y)
    # resuming at step 2 reproduces the same batch (restart determinism)
    c = synthetic_lm_batches(1000, 4, 16, seed=7, start_step=2)
    np.testing.assert_array_equal(next(c), first_a[2])


def test_pack_documents():
    tok = ByteTokenizer(512)
    docs = ["hello world", "second document here", "third"]
    windows = pack_documents(docs, tok.encode, seq_len=8, eos_id=tok.eos_id)
    assert windows.ndim == 2 and windows.shape[1] == 8
    assert (windows >= 0).all() and (windows < 512).all()


def test_prefetcher_preserves_order():
    it = iter([np.full((2,), i) for i in range(5)])
    pf = Prefetcher(it, depth=2)
    got = [int(x[0]) for x in pf]
    assert got == [0, 1, 2, 3, 4]


def test_host_batch_slice():
    from repro.data.loader import host_batch_slice

    assert host_batch_slice(256, 3, 16) == (48, 64)
    with pytest.raises(ValueError):
        host_batch_slice(255, 0, 16)
