"""Serving cluster (DESIGN.md §12): data-parallel engine replicas behind
a prefix-affinity router — parity with a single engine, routing policy,
failover, and merged accounting.

The cluster engines run the full serving stack (radix prefix cache +
paged KV + self-speculative decode, all forced on) so cluster-vs-single
parity covers every layer at once.  ``REPRO_REPLICAS`` sizes the cluster
(CI runs a leg with 2 replicas over 4 forced host devices).
"""

import os
import threading
import time

import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_smoke_config
from repro.core import adaptive_join, block_join
from repro.core.accounting import Usage, ZERO_USAGE
from repro.core.oracle import OracleLLM
from repro.core.prompts import (
    block_prompt,
    block_prompt_shared_prefix,
    block_prompt_variable_suffix,
    split_shared_prefix,
)
from repro.data.tokenizer import ByteTokenizer
from repro.models import init_params, model_specs
from repro.serve import (
    Cluster,
    ClusterClient,
    Engine,
    EngineClient,
    PrefixAffinityRouter,
    RoundRobinRouter,
    RouterView,
    affinity_key,
)

KEY = jax.random.PRNGKey(7)
REPLICAS = max(2, int(os.environ.get("REPRO_REPLICAS", "2")))
ENGINE_KW = dict(max_seq=512, slots=4, prefix_cache=True, spec_decode=True)


def make_tables(n1=8, n2=16):
    colours = ["red", "blue"]
    left = [f"item {i} in {colours[i % 2]}" for i in range(n1)]
    right = [f"want {k} {colours[k % 2]}" for k in range(n2)]
    pred = lambda a, b: a.split()[-1] == b.split()[-1]
    truth = {(i, k) for i, a in enumerate(left)
             for k, b in enumerate(right) if pred(a, b)}
    return left, right, pred, truth


@pytest.fixture(scope="module")
def params():
    cfg = get_smoke_config("granite-3-2b")
    return cfg, init_params(model_specs(cfg), KEY, jnp.float32)


@pytest.fixture(scope="module")
def single_engine(params):
    cfg, p = params
    return Engine(cfg, p, ByteTokenizer(cfg.vocab_size), **ENGINE_KW)


@pytest.fixture(scope="module")
def cluster(params):
    cfg, p = params
    cl = Cluster.replicate(cfg, p, ByteTokenizer(cfg.vocab_size), REPLICAS,
                           **ENGINE_KW)
    yield cl
    cl.shutdown()


# ---------------------------------------------------------------------------
# routing key + router policy (host-side, no engines)
# ---------------------------------------------------------------------------


def test_affinity_key_is_the_canonical_prefix_split():
    b1 = ["alpha text", "beta text"]
    b2a, b2b = ["gamma"], ["delta", "epsilon"]
    pa = block_prompt(b1, b2a, "cond")
    pb = block_prompt(b1, b2b, "cond")
    prefix, suffix = split_shared_prefix(pa)
    assert prefix == block_prompt_shared_prefix(b1, "cond")
    assert suffix == block_prompt_variable_suffix(b2a)
    assert prefix + suffix == pa
    # same left block -> same key; different left block -> different key
    assert affinity_key(pa) == affinity_key(pb)
    assert affinity_key(pa) != affinity_key(block_prompt(["other"], b2a, "cond"))
    # markerless prompts are their own key
    assert affinity_key("Q: hi\nA:") == "Q: hi\nA:"


def test_prefix_affinity_router_policy():
    r = PrefixAffinityRouter(spill_factor=1.0)
    view = lambda out: RouterView(alive=[0, 1], outstanding=out,
                                  capacity={0: 100, 1: 100})
    # new keys go least-outstanding (ties -> lowest id)
    assert r.pick("a", 10, view({0: 0, 1: 0})) == 0
    assert r.pick("b", 10, view({0: 50, 1: 0})) == 1
    # affinity holds while imbalance stays within spill_factor batches
    assert r.pick("a", 10, view({0: 90, 1: 0})) == 0
    assert r.pick("a", 10, view({0: 100, 1: 10})) == 0
    # beyond it, the prompt spills to the least-loaded replica
    assert r.pick("a", 10, view({0: 150, 1: 10})) == 1
    assert r.stats.spills == 1 and r.stats.new_keys == 2
    # a dead home is re-pinned to a survivor
    dead = RouterView(alive=[1], outstanding={0: 0, 1: 40},
                      capacity={0: 100, 1: 100})
    assert r.pick("a", 10, dead) == 1
    assert r.stats.rehomed_keys == 1
    assert r.pick("a", 10, view({0: 0, 1: 40})) == 1  # re-pin sticks


def test_affinity_table_is_lru_bounded():
    """Markerless traffic makes every prompt its own key — the table
    must not grow one entry per request forever (regression)."""
    r = PrefixAffinityRouter(max_keys=2)
    view = RouterView(alive=[0, 1], outstanding={0: 0, 1: 0},
                      capacity={0: 100, 1: 100})
    for key in ["a", "b", "c"]:
        r.pick(key, 1, view)
    assert len(r._home) == 2 and "a" not in r._home  # LRU evicted
    r.pick("b", 1, view)  # touch keeps "b" hot...
    r.pick("d", 1, view)
    assert "b" in r._home and "c" not in r._home  # ...so "c" went instead
    assert r.stats.new_keys == 4  # an evicted key routes as new


def test_round_robin_router_cycles():
    r = RoundRobinRouter()
    view = RouterView(alive=[0, 2], outstanding={0: 0, 2: 999},
                      capacity={0: 1, 2: 1})
    assert [r.pick("k", 1, view) for _ in range(4)] == [0, 2, 0, 2]


# ---------------------------------------------------------------------------
# cluster vs single engine: token-identical serving
# ---------------------------------------------------------------------------


def test_cluster_generation_matches_single_engine(single_engine, cluster):
    """Every prompt must decode to the same text on the cluster as on a
    lone engine (greedy decode; prefix cache + paged KV + spec decode
    on) — routing must never change a token."""
    prompts = [f"request {i}: describe item {i % 3}\nAnswer:"
               for i in range(10)]
    expected = [f"ans {i % 4}; Finished" for i in range(10)]
    solo = single_engine.generate(prompts, max_tokens=16, expected=expected)
    handles = [cluster.submit(p, max_tokens=16, expected=e)
               for p, e in zip(prompts, expected)]
    for h, s in zip(handles, solo):
        r = cluster.result(h)
        assert r.text == s.text
        assert r.prompt_tokens == s.prompt_tokens
        assert r.completion_tokens == s.completion_tokens


def test_cluster_block_join_parity_and_merged_accounting(
        params, single_engine, cluster):
    left, right, pred, truth = make_tables()
    ref = block_join(left, right, "the colours match",
                     EngineClient(single_engine,
                                  oracle=OracleLLM(pred, context_limit=512)),
                     4, 2)
    base_ledger = cluster.ledger()  # the module-scoped cluster is shared
    client = ClusterClient(cluster, oracle=OracleLLM(pred, context_limit=512))
    res = block_join(left, right, "the colours match", client, 4, 2)
    assert res.pairs == ref.pairs == truth
    # token-identical: same calls, same prompt and completion tokens
    assert res.ledger.calls == ref.ledger.calls
    assert res.ledger.prompt_tokens == ref.ledger.prompt_tokens
    assert res.ledger.completion_tokens == ref.ledger.completion_tokens

    # merged accounting: per-replica ledgers sum exactly to the cluster
    # ledger, and this join's delta matches what the join itself booked
    merged = cluster.ledger()
    assert merged.usage == sum(
        (l.usage for l in cluster.replica_ledgers()), ZERO_USAGE)
    assert sum(l.calls for l in cluster.replica_ledgers()) == merged.calls
    delta = Usage(
        merged.prompt_tokens - base_ledger.prompt_tokens,
        merged.completion_tokens - base_ledger.completion_tokens,
        merged.cached_prompt_tokens - base_ledger.cached_prompt_tokens,
        merged.drafted_tokens - base_ledger.drafted_tokens,
        merged.accepted_draft_tokens - base_ledger.accepted_draft_tokens,
    )
    assert delta == res.ledger.usage
    # merged ExecutorStats are the field-wise sum of the replica stats
    stats = cluster.stats()
    per = cluster.replica_stats()
    assert stats.generated_tokens == sum(s.generated_tokens for s in per)
    assert stats.decode_steps == sum(s.decode_steps for s in per)
    assert stats.prefill_tokens_computed + stats.prefill_tokens_cached == \
        sum(s.prefill_tokens_computed + s.prefill_tokens_cached for s in per)


def test_adaptive_join_through_cluster(cluster):
    left, right, pred, truth = make_tables(6, 8)
    client = ClusterClient(cluster, oracle=OracleLLM(pred, context_limit=512))
    assert client.prefix_cached  # advertised to the batch-size optimizer
    res = adaptive_join(left, right, "the colours match", client,
                        initial_estimate=1e-3)
    assert res.pairs == truth
    assert res.meta["prefix_cached"]


def test_cluster_cancel(cluster):
    handles = [cluster.submit(f"cancel probe {i}:", max_tokens=8,
                              expected="zz") for i in range(12)]
    outcomes = [cluster.cancel(h) for h in reversed(handles[6:])]
    cluster.drain()
    for h, ok in zip(reversed(handles[6:]), outcomes):
        if ok:  # cancelled before a worker picked it up: stays result-less
            assert h.status == "cancelled" and h.result is None
        else:   # a worker won the race: it must then have finished
            assert h.status == "finished"
    for h in handles[:6]:
        assert cluster.result(h).completion_tokens > 0


# ---------------------------------------------------------------------------
# routing policy vs cache locality
# ---------------------------------------------------------------------------


def _join_hit_rate(params, router, left, right, pred):
    cfg, p = params
    with Cluster.replicate(cfg, p, ByteTokenizer(cfg.vocab_size), REPLICAS,
                           router=router, **ENGINE_KW) as cl:
        client = ClusterClient(cl, oracle=OracleLLM(pred, context_limit=512))
        cl.hold()  # gang submission: deterministic routing + batching
        res = block_join(left, right, "the colours match", client, 4, 2)
        cl.drain()
        return res, cl.prefix_cache_stats()["hit_rate"], cl


def test_affinity_routing_preserves_cache_hit_rate(params):
    """Acceptance: prefix-affinity keeps the cluster's radix-cache hit
    rate at >= 90% of a single engine's on the block-join workload,
    while round-robin routing measurably degrades it (every replica
    recomputes every left-block prefix)."""
    left, right, pred, truth = make_tables(16, 16)
    cfg, p = params
    eng = Engine(cfg, p, ByteTokenizer(cfg.vocab_size), **ENGINE_KW)
    ref = block_join(left, right, "the colours match",
                     EngineClient(eng, oracle=OracleLLM(pred, context_limit=512)),
                     4, 2)
    single_rate = eng.prefix_cache_stats()["hit_rate"]
    assert ref.pairs == truth and single_rate > 0

    res_a, rate_affinity, _ = _join_hit_rate(
        params, PrefixAffinityRouter(), left, right, pred)
    res_r, rate_rr, _ = _join_hit_rate(
        params, RoundRobinRouter(), left, right, pred)
    assert res_a.pairs == res_r.pairs == truth
    assert rate_affinity >= 0.9 * single_rate
    assert rate_rr < rate_affinity  # blind balancing shreds locality


# ---------------------------------------------------------------------------
# failover
# ---------------------------------------------------------------------------


def test_replica_failure_mid_join_completes_token_identical(
        params, single_engine):
    """Killing a replica mid-join fails its in-flight + queued prompts
    over to the survivors (through the executor's requeue path) and the
    join still completes with token-identical results."""
    left, right, pred, truth = make_tables()
    ref = block_join(left, right, "the colours match",
                     EngineClient(single_engine,
                                  oracle=OracleLLM(pred, context_limit=512)),
                     4, 2)
    cfg, p = params
    with Cluster.replicate(cfg, p, ByteTokenizer(cfg.vocab_size), REPLICAS,
                           **ENGINE_KW) as cl:
        client = ClusterClient(cl, oracle=OracleLLM(pred, context_limit=512))
        killer = threading.Timer(0.3, cl.fail_replica, args=(1,))
        killer.start()
        try:
            res = block_join(left, right, "the colours match", client, 4, 2)
        finally:
            killer.cancel()
        cl.fail_replica(1)  # idempotent if the join outran the timer
        cl.drain()
        assert res.pairs == ref.pairs == truth
        assert res.ledger.calls == ref.ledger.calls
        assert res.ledger.completion_tokens == ref.ledger.completion_tokens
        assert cl.replicas_alive == REPLICAS - 1
        # the dead replica's ledger only holds requests it finished;
        # conservation still exact after the handoff
        assert cl.ledger().usage == sum(
            (l.usage for l in cl.replica_ledgers()), ZERO_USAGE)
        assert cl.ledger().usage == res.ledger.usage


def test_engine_exception_triggers_failover(params, monkeypatch):
    """A replica whose engine keeps raising (executor retries exhausted)
    is torn down by its own worker and its work completes elsewhere."""
    # this test injects its own deterministic fault and pins max_retries=1;
    # ambient chaos would exhaust retries on the *good* replica too
    monkeypatch.delenv("REPRO_CHAOS", raising=False)
    cfg, p = params
    with Cluster.replicate(cfg, p, ByteTokenizer(cfg.vocab_size), 2,
                           max_retries=1, **ENGINE_KW) as cl:
        bad = cl.engines[1]
        down = lambda *a, **k: (_ for _ in ()).throw(
            RuntimeError("replica 1 is down"))
        monkeypatch.setattr(bad, "decode_active", down)
        monkeypatch.setattr(bad, "verify_active", down)
        monkeypatch.setattr(bad, "prefill_rows", down)
        handles = [cl.submit(f"fo {i}:", max_tokens=4, expected="ok")
                   for i in range(8)]
        for h in handles:
            assert cl.result(h).completion_tokens > 0
        assert cl.replicas_alive == 1
        assert any(h.failovers > 0 for h in handles) or all(
            h.replica == 0 for h in handles)


def test_all_replicas_dead_raises(params):
    cfg, p = params
    cl = Cluster.replicate(cfg, p, ByteTokenizer(cfg.vocab_size), 2,
                           **ENGINE_KW)
    h = cl.submit("doomed:", max_tokens=8, expected="x " * 64)
    cl.fail_replica(0)
    cl.fail_replica(1)
    deadline = time.time() + 60
    while cl.replicas_alive and time.time() < deadline:
        time.sleep(0.01)
    assert cl.replicas_alive == 0
    # the doomed request either finished before the lights went out or
    # its wait raises — never hangs
    try:
        cl.result(h)
    except RuntimeError:
        pass
    with pytest.raises(RuntimeError):
        cl.submit("after the lights went out:", max_tokens=4)
    cl.shutdown()


def test_cancel_on_fatal_cluster_returns_instead_of_spinning(params):
    """Regression: a request orphaned by a fatal failure (all replicas
    dead) must make cancel() return False — block_join's exception
    cleanup calls cancel on every unfinished handle and used to spin."""
    cfg, p = params
    cl = Cluster.replicate(cfg, p, ByteTokenizer(cfg.vocab_size), 1,
                           **ENGINE_KW)
    cl.hold()  # keep the request queued so the failure orphans it
    h = cl.submit("stranded:", max_tokens=8, expected="never")
    cl.fail_replica(0)
    deadline = time.time() + 60
    while cl.replicas_alive and time.time() < deadline:
        time.sleep(0.01)
    t0 = time.time()
    assert cl.cancel(h) is False
    assert time.time() - t0 < 5  # returned, not busy-looped
    with pytest.raises(RuntimeError):
        cl.result(h)
    cl.shutdown()
