"""Paged-KV serving (DESIGN.md §10): refcounted page-pool unit tests, an
allocator-churn hypothesis property, zero-copy prefix sharing, page
-budget admission, and the paged-vs-dense engine parity suite.

The headline property: the engine's outputs, finish reasons, and token
accounting are *identical* with REPRO_PAGED_KV on vs off — including
mid-decode slot refill and prefix-cache hits.  Paging may only change
*where* KV bytes live (one shared refcounted pool vs dense slot rows),
never what is generated or billed.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.data.tokenizer import ByteTokenizer
from repro.models import init_params, model_specs
from repro.serve import Engine, PagedKVPool
from repro.serve.engine import PagedDecodeState, _bucket

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:  # dev-only dep; see requirements-dev.txt
    HAVE_HYPOTHESIS = False

KEY = jax.random.PRNGKey(11)


# ---------------------------------------------------------------------------
# Refcounted page pool (no model involved)
# ---------------------------------------------------------------------------


def test_pool_refcount_lifecycle():
    pool = PagedKVPool(8, 4)
    a = pool.alloc(3)
    assert a is not None and pool.free_pages == 5
    assert all(pool.writable(p) for p in a)          # exclusive writers
    pool.incref(a[:2])                               # share two read-only
    assert not pool.writable(a[0]) and pool.writable(a[2])
    pool.decref(a)                                   # row retires
    assert pool.free_pages == 6                      # a[2] freed, a[0:2] live
    pool.decref(a[:2])                               # tree evicts
    assert pool.free_pages == 8
    assert (pool.refs == 0).all()
    with pytest.raises(ValueError):
        pool.decref([a[0]])                          # double free


def test_pool_alloc_exhaustion_and_peak():
    pool = PagedKVPool(4, 4)
    a = pool.alloc(3)
    assert pool.alloc(2) is None                     # only 1 free
    assert pool.alloc(1) is not None
    assert pool.peak_pages == 4
    pool.decref(a)
    assert pool.peak_pages == 4                      # high-water sticks


def test_pool_copy_on_write_payload_and_refs():
    pool = PagedKVPool(4, 2)
    pool.bind(jnp.zeros((1, 1, 8, 1, 2)), jnp.zeros((1, 1, 8, 1, 2)))
    (src,) = pool.alloc(1)
    payload = jnp.arange(4, dtype=jnp.float32).reshape(1, 1, 2, 1, 2)
    pool.write([src], payload, payload + 10)
    pool.incref([src])                               # shared: row + tree
    dst = pool.copy_page(src)
    assert dst != src
    assert pool.writable(dst)                        # the copy is exclusive
    assert pool.refs[src] == 1                       # caller's ref moved off
    k, v = pool.gather(np.asarray([[dst]], np.int32))
    np.testing.assert_array_equal(np.asarray(k), np.asarray(payload))
    np.testing.assert_array_equal(np.asarray(v), np.asarray(payload + 10))


# ---------------------------------------------------------------------------
# Allocator churn property: alloc / free / share / CoW interleavings
# ---------------------------------------------------------------------------

if HAVE_HYPOTHESIS:

    @given(st.lists(st.tuples(st.sampled_from(["alloc", "free", "share",
                                               "unshare", "cow"]),
                              st.integers(0, 10 ** 6)),
                    min_size=1, max_size=60))
    @settings(max_examples=50, deadline=None)
    def test_page_allocator_churn_property(ops):
        """Interleaved alloc/free/share/CoW ops: no page is ever
        referenced by two writers, refcounts drain to zero, and
        free + allocated is conserved at every step."""
        N = 12
        pool = PagedKVPool(N, 2)
        writers = []    # pages owned exclusively by a simulated row
        shared = []     # extra (read-only) references, tree-style

        def check():
            assert pool.free_pages + pool.allocated_pages == N
            counts = {}
            for p in writers + shared:
                counts[p] = counts.get(p, 0) + 1
            for p, c in counts.items():
                assert pool.refs[p] == c
            # single-writer invariant: a page listed as a writer target
            # is writable iff no other reference exists
            for p in set(writers):
                assert writers.count(p) == 1          # never two writers
                assert pool.writable(p) == (p not in shared)
            for p in range(N):
                held = counts.get(p, 0)
                assert (pool.refs[p] == 0) == (held == 0)

        for op, arg in ops:
            if op == "alloc":
                got = pool.alloc(arg % 3 + 1)
                if got is not None:
                    writers.extend(got)
            elif op == "free" and writers:
                pool.decref([writers.pop(arg % len(writers))])
            elif op == "share" and writers:
                p = writers[arg % len(writers)]
                pool.incref([p])
                shared.append(p)
            elif op == "unshare" and shared:
                pool.decref([shared.pop(arg % len(shared))])
            elif op == "cow" and writers:
                i = arg % len(writers)
                if not pool.writable(writers[i]):
                    new = pool.copy_page(writers[i])
                    if new is not None:
                        writers[i] = new
            check()

        # drain: every reference released → empty pool, all refs zero
        pool.decref(writers)
        pool.decref(shared)
        assert pool.free_pages == N
        assert (pool.refs == 0).all()


# ---------------------------------------------------------------------------
# _bucket regression: raise, never clamp/truncate
# ---------------------------------------------------------------------------


def test_bucket_raises_instead_of_clamping():
    assert _bucket(100, (64, 128, 256)) == 128
    with pytest.raises(ValueError, match="exceeds the largest prefill bucket"):
        _bucket(300, (64, 128, 256))


# ---------------------------------------------------------------------------
# Engine-level paged-KV behavior
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def params():
    cfg = get_smoke_config("granite-3-2b")
    return init_params(model_specs(cfg), KEY, jnp.float32)


def _engine(params, **kw):
    cfg = get_smoke_config("granite-3-2b")
    kw.setdefault("max_seq", 256)
    kw.setdefault("slots", 3)
    kw.setdefault("prefill_buckets", (64, 128, 256))
    return Engine(cfg, params, ByteTokenizer(cfg.vocab_size), **kw)


def _run(engine, requests):
    """requests: [(prompt, max_tokens, stop, expected)] → (executor, results)."""
    ex = engine.executor()
    handles = [ex.submit(p, max_tokens=mt, stop=stop, expected=exp)
               for (p, mt, stop, exp) in requests]
    ex.drain()
    return ex, [h.result for h in handles]


def _assert_parity(ex_a, ex_b, res_a, res_b):
    for a, b in zip(res_a, res_b):
        assert a.text == b.text
        assert a.finish_reason == b.finish_reason
        assert a.prompt_tokens == b.prompt_tokens
        assert a.completion_tokens == b.completion_tokens
        assert a.cached_prompt_tokens == b.cached_prompt_tokens
    assert ex_a.stats.generated_tokens == ex_b.stats.generated_tokens
    assert (ex_a.stats.prefill_tokens_computed
            == ex_b.stats.prefill_tokens_computed)
    assert ex_a.stats.prefill_tokens_cached == ex_b.stats.prefill_tokens_cached


def test_engine_rejects_overlong_prompt_instead_of_truncating(params):
    """Regression for the _bucket clamp: a prompt longer than every
    bucket must be rejected loudly, never silently truncated to the
    largest bucket.  Prompts above the largest *configured* bucket but
    within max_seq get a max_seq bucket automatically."""
    eng = _engine(params, prefill_buckets=(64,), max_seq=256, paged=False,
                  prefix_cache=False)
    assert eng.prefill_buckets[-1] == 256  # max_seq always bucketed
    mid = "m" * 120   # beyond the configured 64-bucket, within max_seq
    res = eng.generate([mid], max_tokens=2, expected=["ok"])[0]
    assert res.prompt_tokens > 64
    over = "x" * 300  # beyond max_seq
    ex = eng.executor()
    with pytest.raises(ValueError, match="exceeds engine max_seq"):
        ex.submit(over, max_tokens=2)


def test_greedy_parity_paged_vs_dense_no_prefix_cache(params):
    """Greedy decode through page tables must not change a single sampled
    token vs the dense engine — including mid-decode slot refill (more
    requests than slots)."""
    shared = "Parity preamble long enough to span multiple pages here: " * 2
    reqs = [(shared + f"tail {i}", 8, None, None) for i in range(7)]
    ex_p, res_p = _run(_engine(params, paged=True, prefix_cache=False), reqs)
    ex_d, res_d = _run(_engine(params, paged=False, prefix_cache=False), reqs)
    _assert_parity(ex_p, ex_d, res_p, res_d)
    assert ex_p.stats.refills == len(reqs) > 3  # refill path exercised


def test_greedy_parity_paged_vs_dense_with_prefix_hits(params):
    """The zero-copy prefix-sharing path (paged) vs the gather/copy-in
    path (dense): identical outputs AND identical cached-token
    accounting — the radix tree sees the same interning either way."""
    shared = "Shared instruction header, quite long so pages align: " * 2
    reqs = [(shared + f"variable tail number {i}", 8, None, None)
            for i in range(7)]
    eng_p = _engine(params, paged=True, prefix_cache=True)
    eng_d = _engine(params, paged=False, prefix_cache=True)
    ex_p, res_p = _run(eng_p, reqs)
    ex_d, res_d = _run(eng_d, reqs)
    _assert_parity(ex_p, ex_d, res_p, res_d)
    assert ex_p.stats.prefill_tokens_cached > 0      # the cache actually hit
    assert eng_p.prefix_cache.stats.shared_pages > 0  # interned by reference
    assert eng_d.prefix_cache.stats.shared_pages == 0  # dense copies


def test_parity_with_stops_budgets_and_repeat_prompts(params):
    """Heterogeneous stops/budgets + byte-identical re-submissions (the
    full-hit, CoW-adjacent path) stay token-identical across modes."""
    shared = "Stop-string parity preamble shared across the batch here: " * 2
    reqs = [
        (shared + "q1", 32, "DONE", "xy DONE zz"),
        (shared + "q2", 3, None, "abcdefghij"),
        (shared + "q1", 32, "DONE", "xy DONE zz"),   # exact repeat
        (shared + "q3", 32, "END", "pq END rr"),
        (shared + "q2", 6, None, "abcdefghij"),      # repeat, other budget
    ]
    ex_p, res_p = _run(_engine(params, paged=True, prefix_cache=True), reqs)
    ex_d, res_d = _run(_engine(params, paged=False, prefix_cache=True), reqs)
    _assert_parity(ex_p, ex_d, res_p, res_d)
    assert res_p[0].finish_reason == "stop"
    assert res_p[1].finish_reason == "length"


def test_zero_copy_sharing_and_refcounts(params):
    """A prefix hit must reference the cached pages, not copy them: the
    new row's table starts with the *same page ids* the tree holds, at
    refcount >= 2, and nothing is written to them."""
    eng = _engine(params, paged=True, prefix_cache=True)
    shared = "Zero copy sharing check preamble padded out to pages: " * 2
    eng.generate([shared + "first tail"], max_tokens=2, expected=["a"])
    tree_pages = set(eng.prefix_cache.tree_pages())
    assert tree_pages and all(eng.pool.refs[p] >= 1 for p in tree_pages)

    ex = eng.executor()
    h = ex.submit(shared + "second tail", max_tokens=2, expected="b")
    ex.step()  # admit + prefill (decode not finished yet)
    state = ex._state
    assert isinstance(state, PagedDecodeState)
    table = state.tables[h._slot]
    n_shared = h._cached_prompt // eng.page_size
    assert n_shared > 0
    shared_pages = table[:n_shared]
    assert set(shared_pages) <= tree_pages            # same ids — no copy
    assert all(eng.pool.refs[p] >= 2 for p in shared_pages)
    assert all(not eng.pool.writable(p) for p in shared_pages)  # read-only
    ex.drain()
    # retirement dropped the row's references; the tree's survive
    assert all(eng.pool.refs[p] >= 1 for p in shared_pages)


def test_in_batch_dedup_of_cold_shared_prefixes(params):
    """A cold burst (several rows of one left block admitted in a single
    refill, before the tree knows the prefix) must store the shared full
    pages ONCE, not once per row — each row's table references the same
    page ids, at refcount == number of sharers."""
    eng = _engine(params, paged=True, prefix_cache=True)
    shared = "Cold burst shared left block content spanning pages: " * 3
    prompts = [shared + f"tail {i}" for i in range(3)]  # one batch (3 slots)
    ex = eng.executor()
    hs = [ex.submit(p, max_tokens=4, expected="ok") for p in prompts]
    ex.step()  # single refill: all three admitted cold
    assert all(h.status == "active" for h in hs)
    assert all(h._cached_prompt == 0 for h in hs)  # tree was cold
    state = ex._state
    tables = [state.tables[h._slot] for h in hs]
    n_shared = eng.count_tokens(shared) // eng.page_size - 1
    assert n_shared > 2
    head = tables[0][:n_shared]
    for t in tables[1:]:
        assert t[:n_shared] == head                 # same ids — stored once
    # refs: 3 rows + the radix tree's zero-copy intern
    assert all(eng.pool.refs[p] == 4 for p in head)
    live = set().union(*tables)
    assert len(live) < sum(len(t) for t in tables)  # genuinely deduped
    ex.drain()
    for a, b in zip(hs, _run(_engine(params, paged=False,
                                     prefix_cache=True),
                             [(p, 4, None, "ok") for p in prompts])[1]):
        assert a.result.text == b.text              # dedup is storage-only


def test_pages_drain_on_retire_cancel_and_failure(params, monkeypatch):
    """Every page allocated for a row is released on retire, on active
    cancel, and on engine-failure requeue — only tree references remain."""
    eng = _engine(params, paged=True, prefix_cache=True)
    ex = eng.executor()
    hs = [ex.submit(f"drain check prompt {i} padded out somewhat: ",
                    max_tokens=4, expected="ok") for i in range(5)]
    ex.step()
    ex.cancel(hs[1]) if hs[1].status == "active" else None
    ex.drain()
    tree = eng.prefix_cache.tree_pages()
    assert eng.pool.allocated_pages - 1 == len(tree)  # sans dump page
    assert ex._used_pages == 0

    # engine failure mid-decode: requeue must drop page references too
    ex2 = eng.executor(max_retries=2)
    h = ex2.submit("failure requeue prompt padded: ", max_tokens=3,
                   expected="ok")
    failures = iter([True])

    def make_flaky(real):
        def flaky(*args, **kw):
            if next(failures, False):
                raise RuntimeError("injected engine failure")
            return real(*args, **kw)
        return flaky

    # a spec-decode engine steps through verify_active instead of
    # decode_active — inject into whichever the env selects
    monkeypatch.setattr(eng, "decode_active", make_flaky(eng.decode_active))
    monkeypatch.setattr(eng, "verify_active", make_flaky(eng.verify_active))
    ex2.drain()
    assert h.result is not None and h.retries == 1
    assert eng.pool.allocated_pages - 1 == len(eng.prefix_cache.tree_pages())
    assert ex2._used_pages == 0


def test_no_prefix_cache_pool_fully_drains(params):
    eng = _engine(params, paged=True, prefix_cache=False)
    eng.generate([f"fully drained prompt {i}" for i in range(4)],
                 max_tokens=4, expected=["a", "bb", "c", "dd"])
    assert eng.pool.allocated_pages == 1  # only the pinned dump page


def test_admission_bounded_by_free_pages(params):
    """A pool smaller than slots × max_seq limits concurrency by *pages*:
    requests are admitted only while their worst-case reservation fits,
    and a request that could never fit is rejected at submit."""
    # 20 usable pages of 16 tokens = 320 token-slots, vs 3×256 = 768
    eng = _engine(params, paged=True, prefix_cache=False, pool_pages=20)
    ex = eng.executor()
    hs = [ex.submit("admission page budget prompt " + "p" * 40,
                    max_tokens=100, expected="x" * 6) for i in range(3)]
    ex.step()
    active = [h for h in hs if h.status == "active"]
    # each needs ceil((~70 + 100)/16) ≈ 11 pages → only 1 fits in 20
    assert 0 < len(active) < 3
    assert sum(h._pages for h in active) <= eng.total_kv_pages
    ex.drain()
    assert all(h.result is not None for h in hs)

    # a request whose worst case exceeds the whole pool is rejected at
    # submit — it could never be admitted
    tiny = _engine(params, paged=True, prefix_cache=False, pool_pages=10)
    with pytest.raises(ValueError, match="could never be admitted"):
        tiny.executor().submit("q" * 200, max_tokens=100)  # needs 16 > 10


def test_decode_appends_in_place_across_page_boundaries(params):
    """A generation long enough to cross page boundaries allocates fresh
    pages mid-decode and the row's table grows accordingly."""
    eng = _engine(params, paged=True, prefix_cache=False, page_size=16)
    ex = eng.executor()
    h = ex.submit("boundary", max_tokens=40, expected="z" * 40)
    ex.step()
    pages_after_prefill = len(ex._state.tables[h._slot])
    ex.drain()
    assert h.result.completion_tokens == 40
    prompt = h.prompt_tokens
    expect = -(-(prompt + 40 - 1) // 16)  # pages for every written position
    assert pages_after_prefill == -(-prompt // 16)
    assert eng.pool.peak_pages - 1 >= expect


def test_paged_cache_specs_match_engine_layout(params):
    """The abstract paged cache tree (models.cache_specs) must describe
    exactly what the engine constructs at runtime — pool shapes, page
    -table width, dtypes — so dry-run cost estimates cannot drift from
    the real thing."""
    from repro.models import cache_specs

    eng = _engine(params, paged=True, prefix_cache=False)
    eng.generate(["spec layout pin"], max_tokens=2, expected=["a"])
    cfg = get_smoke_config("granite-3-2b")
    specs = cache_specs(cfg, eng.slots, eng.max_seq,
                        page_size=eng.page_size, n_pages=eng.pool.n_pages)
    assert set(specs) == {"len", "pages", "k", "v"}
    assert specs["k"].shape == eng.pool.k.shape
    assert specs["v"].shape == eng.pool.v.shape
    assert specs["pages"].shape == (eng.slots, eng._maxp)
    assert specs["len"].shape == (eng.slots,)
    assert specs["k"].axes == ("layers", "pages", "page", "kv_heads",
                               "head_dim")
    # max_seq not a multiple of the page size: the partial final page
    # still needs a table slot (ceil, matching engine._maxp)
    ragged = _engine(params, paged=True, prefix_cache=False, max_seq=250)
    rspecs = cache_specs(cfg, ragged.slots, 250, page_size=16,
                         n_pages=ragged.pool.n_pages)
    assert rspecs["pages"].shape == (ragged.slots, ragged._maxp) \
        == (ragged.slots, 16)
    with pytest.raises(ValueError, match="KV-only"):
        cache_specs(get_smoke_config("mamba2-130m"), 2, 64,
                    page_size=16, n_pages=8)
    with pytest.raises(ValueError, match="n_pages"):
        cache_specs(cfg, 2, 64, page_size=16)


def test_ssm_family_gates_paged_off(params):
    del params
    cfg = get_smoke_config("mamba2-130m")
    p = init_params(model_specs(cfg), KEY, jnp.float32)
    eng = Engine(cfg, p, ByteTokenizer(cfg.vocab_size), max_seq=128,
                 slots=2, paged=True)
    assert not eng.paged and eng.pool is None and eng.kv_stats() is None


def test_env_var_gates_paged(params, monkeypatch):
    monkeypatch.setenv("REPRO_PAGED_KV", "0")
    assert not _engine(params).paged
    monkeypatch.setenv("REPRO_PAGED_KV", "1")
    assert _engine(params).paged
    # explicit arg wins over env
    monkeypatch.setenv("REPRO_PAGED_KV", "1")
    assert not _engine(params, paged=False).paged
